"""Serving paths: prefill (build decode state) and single-token decode.

Decode caches are *ring buffers*: slot = pos % W with W = min(window, S_max)
for sliding-window layers and W = S_max for full-attention layers. The
absolute position of slot j at time pos is p_j = pos - ((pos - j) % W),
which yields the correct causal/sliding mask for both cases with one
formula. SSM layers (RWKV6 / Mamba) carry O(1) recurrent states instead —
that is why those archs run the long_500k cell.

Cache sharding (see launch/shardings.py): the ring axis W is sharded over
the ``model`` mesh axis — attention against the cache then reduces tiny
[B,H]-sized partial softmax statistics over ``model`` instead of gathering
the cache (the decode-side analog of the paper's "communicate the small
thing, not the vectors").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import ssm
from .attention import NEG_INF, _qkv
from .config import ModelConfig
from .layers import apply_linear, apply_mlp, apply_norm, embed, unembed
from .transformer import lm_head_table
from . import moe as moe_mod


# ----------------------------------------------------------- ring caches --

def ring_update(ck, cv, k, v, pos):
    """ck/cv [B,W,H,hd]; k/v [B,1,H,hd]; write slot pos % W."""
    W = ck.shape[1]
    slot = pos % W
    ck = lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), slot, axis=1)
    cv = lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), slot, axis=1)
    return ck, cv


def ring_attend(p, cfg: ModelConfig, q, ck, cv, pos, window):
    """q [B,1,H,hd] (rope applied); returns attention output [B,1,q_dim]."""
    B = q.shape[0]
    W = ck.shape[1]
    group = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, 1, cfg.n_kv_heads, group, cfg.hd)
    scale = float(1.0 / np.sqrt(cfg.hd))
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, ck,
                   preferred_element_type=jnp.float32) * scale
    j = jnp.arange(W)
    p_j = pos - ((pos - j) % W)  # absolute position stored in slot j
    mask = (p_j >= 0) & (p_j <= pos)
    w_lim = jnp.where(jnp.asarray(window) > 0, window, W + pos + 2)
    mask &= p_j > pos - w_lim
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", pr.astype(jnp.float32),
                     cv.astype(jnp.float32))
    return out.reshape(B, 1, cfg.q_dim).astype(q.dtype)


def attn_decode(p, cfg: ModelConfig, x, ck, cv, pos, window):
    q, k, v = _qkv(p, cfg, x, pos[None])
    ck, cv = ring_update(ck, cv, k, v, pos)
    out = ring_attend(p, cfg, q, ck, cv, pos, window)
    return apply_linear(p["wo"], out), ck, cv


# ------------------------------------------------------------ block paths --

def block_decode(lp, cfg: ModelConfig, x, st, pos, window):
    """One layer, one token. st is this layer's state dict."""
    if cfg.family == "ssm":
        xin = apply_norm(lp["norm1"], x)
        y, wkv, x_tm = ssm.rwkv_time_mix(
            lp["time_mix"], cfg, xin, state=st["wkv"], x_prev=st["x_tm"]
        )
        x = x + y
        xin = apply_norm(lp["norm2"], x)
        y, x_cm = ssm.rwkv_channel_mix(lp["channel_mix"], cfg, xin, x_prev=st["x_cm"])
        return x + y, {"wkv": wkv, "x_tm": x_tm, "x_cm": x_cm}
    xin = apply_norm(lp["norm1"], x)
    a, ck, cv = attn_decode(lp["attn"], cfg, xin, st["k"], st["v"], pos, window)
    new_st = {"k": ck, "v": cv}
    if cfg.hybrid:
        m, h_ssm, conv = ssm.mamba_block(
            lp["mamba"], cfg, xin, state=st["ssm"], conv_state=st["conv"]
        )
        a = 0.5 * (apply_norm(lp["norm_attn"], a) + apply_norm(lp["norm_mamba"], m))
        new_st["ssm"], new_st["conv"] = h_ssm, conv
    x = x + a
    xin = apply_norm(lp["norm2"], x)
    if cfg.n_experts:
        y = moe_mod.apply_moe_decode(lp["moe"], cfg, xin)
    else:
        y = apply_mlp(lp["mlp"], xin, cfg.activation)
    return x + y, new_st


# --------------------------------------------------------- state creation --

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """Zero decode state: one entry per homogeneous segment (used by the
    dry-run input_specs and by serving). Sliding-window segments allocate
    ring buffers of the window size only — at 500k context the SWA layers
    hold 2048-deep caches while the 3 global layers hold the full ring."""
    dt = jnp.dtype(dtype or cfg.dtype)
    d = cfg.d_model
    states = []
    for (a, b, w) in cfg.segments():
        Ls = b - a
        if cfg.family == "ssm":
            H = cfg.n_heads
            hd = d // H
            states.append({
                "wkv": jnp.zeros((Ls, batch, H, hd, hd), jnp.float32),
                "x_tm": jnp.zeros((Ls, batch, d), dt),
                "x_cm": jnp.zeros((Ls, batch, d), dt),
            })
            continue
        W = min(w, max_len) if w > 0 else max_len
        st = {
            "k": jnp.zeros((Ls, batch, W, cfg.n_kv_heads, cfg.hd), dt),
            "v": jnp.zeros((Ls, batch, W, cfg.n_kv_heads, cfg.hd), dt),
        }
        if cfg.hybrid:
            st["ssm"] = jnp.zeros((Ls, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
            st["conv"] = jnp.zeros((Ls, batch, 3, cfg.d_inner), dt)
        states.append(st)
    return states


# ------------------------------------------------------------ decode step --

def decode_step(params, cfg: ModelConfig, state, token, pos):
    """One new token for every sequence. token [B] int32; pos scalar int32.
    Returns (logits [B, vocab], new_state)."""
    x = embed(params["embed"], token[:, None])  # [B,1,d]
    new_states = []
    for (a, b, w), blocks, st in zip(cfg.segments(), params["segments"], state):

        def body(x, inp, _w=w):
            lp, s = inp
            x, new_s = block_decode(lp, cfg, x, s, pos, _w)
            return x, new_s

        x, new_st = lax.scan(body, x, (blocks, st))
        new_states.append(new_st)
    h = apply_norm(params["final_norm"], x)
    logits = h[:, 0] @ lm_head_table(params, cfg).T
    return logits, new_states


# ----------------------------------------------------------------- prefill --

def prefill(params, cfg: ModelConfig, batch, max_len: int):
    """Process a full prompt, returning (last-position logits, decode state).

    Implemented as the train-path backbone with per-layer KV collection;
    recurrent layers (rwkv/mamba) return their final states directly.
    """
    from .transformer import backbone_with_state

    return backbone_with_state(params, cfg, batch, max_len)

"""GQA attention: chunked online-softmax (flash-style) for train/prefill,
cache-based single-token path for decode. Pure JAX — the chunked form is
the TPU-right structure (VMEM-sized KV blocks, no S x S score tensor) and
doubles as the oracle for a future Pallas port.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .layers import apply_linear, apply_rope, init_linear, rms_head_norm

NEG_INF = -1e30


def init_attention(key, cfg):
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "wq": init_linear(ks[0], d, cfg.q_dim, cfg, bias=cfg.qkv_bias),
        "wk": init_linear(ks[1], d, cfg.kv_dim, cfg, bias=cfg.qkv_bias),
        "wv": init_linear(ks[2], d, cfg.kv_dim, cfg, bias=cfg.qkv_bias),
        "wo": init_linear(ks[3], cfg.q_dim, d, cfg),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.hd,), jnp.dtype(cfg.dtype))
        p["k_norm"] = jnp.ones((cfg.hd,), jnp.dtype(cfg.dtype))
    return p


def _qkv(p, cfg, x, positions):
    B, S, _ = x.shape
    q = apply_linear(p["wq"], x).reshape(B, S, cfg.n_heads, cfg.hd)
    k = apply_linear(p["wk"], x).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = apply_linear(p["wv"], x).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      chunk: int = 1024, group: int = 1):
    """Online-softmax attention over KV chunks.

    q [B,Sq,H,hd], k/v [B,Sk,Hkv,hd] with H = Hkv*group. Memory per step is
    O(Sq * chunk), never O(Sq * Sk). ``window`` > 0 restricts to a sliding
    window (queries attend to keys in (pos-window, pos]).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    Hkv = k.shape[2]
    chunk = min(chunk, Sk)
    n_chunks = -(-Sk // chunk)
    Sk_pad = n_chunks * chunk
    if Sk_pad != Sk:
        pad = [(0, 0), (0, Sk_pad - Sk), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    kc = k.reshape(B, n_chunks, chunk, Hkv, hd)
    vc = v.reshape(B, n_chunks, chunk, Hkv, hd)
    qg = q.reshape(B, Sq, Hkv, H // Hkv, hd)
    scale = float(1.0 / np.sqrt(hd))
    q_pos = jnp.arange(Sq)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, cidx = inp
        k_pos = cidx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        mask = k_pos[None, :] < Sk
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        # window may be a traced per-layer value; 0 disables it
        w_lim = jnp.where(window > 0, window, Sk + Sq + 2)
        mask &= k_pos[None, :] > q_pos[:, None] - w_lim
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Sq, Hkv, H // Hkv), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, H // Hkv), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, H // Hkv, hd), jnp.float32)
    kc_t = jnp.moveaxis(kc, 1, 0)
    vc_t = jnp.moveaxis(vc, 1, 0)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (kc_t, vc_t, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def attention_block(p, cfg, x, positions, *, causal=True, window=0, return_kv=False):
    """Full attention sublayer for train/prefill. x [B,S,d]."""
    q, k, v = _qkv(p, cfg, x, positions)
    group = cfg.n_heads // cfg.n_kv_heads
    out = chunked_attention(q, k, v, causal=causal, window=window, group=group)
    B, S = x.shape[:2]
    y = apply_linear(p["wo"], out.reshape(B, S, cfg.q_dim))
    if return_kv:
        return y, (k, v)
    return y


# ------------------------------------------------------------------ decode --

def init_kv_cache(cfg, batch, max_len, layers=None, dtype=None):
    L = layers if layers is not None else cfg.n_layers
    dt = jnp.dtype(dtype or cfg.dtype)
    shape = (L, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def decode_attention(p, cfg, x, cache_k, cache_v, pos, *, window=0):
    """Single-token attention against a KV cache.

    x [B,1,d]; cache_k/v [B,Smax,Hkv,hd]; pos scalar int32 (current index).
    Returns (out [B,1,d], new_k, new_v).
    """
    B = x.shape[0]
    q, k, v = _qkv(p, cfg, x, pos[None] if pos.ndim == 0 else pos)
    cache_k = lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, axis=1)
    Smax = cache_k.shape[1]
    group = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, 1, cfg.n_kv_heads, group, cfg.hd)
    scale = float(1.0 / np.sqrt(cfg.hd))
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, cache_k,
                   preferred_element_type=jnp.float32) * scale
    k_pos = jnp.arange(Smax)
    mask = k_pos <= pos
    w_lim = jnp.where(jnp.asarray(window) > 0, window, Smax + 2)
    mask &= k_pos > pos - w_lim
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", pr.astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, cfg.q_dim).astype(x.dtype)
    return apply_linear(p["wo"], out), cache_k, cache_v

"""Mixture-of-experts layer with capacity-based dispatch.

The dispatch is the LM-side instance of the paper's layout switch: tokens
leave the data (vertical) layout, are scattered into expert buffers that
live in the model (horizontal) layout, and are combined back — an explicit
redistribution whose amortization is governed by the same r-vs-s accounting
as Alg. 1 steps 7/9 (see DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .layers import init_linear


def init_moe(key, cfg):
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.d_ff
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.dtype)
    import numpy as np

    def w(k, shape, fan_in):
        return (jax.random.normal(k, shape) / np.sqrt(fan_in)).astype(dt)

    p = {"router": w(ks[0], (d, E), d)}
    if cfg.activation == "swiglu":
        p["experts"] = {
            "gate": w(ks[1], (E, d, ff), d),
            "up": w(ks[2], (E, d, ff), d),
            "down": w(ks[3], (E, ff, d), ff),
        }
    else:
        p["experts"] = {"up": w(ks[1], (E, d, ff), d), "down": w(ks[2], (E, ff, d), ff)}
    if cfg.dense_residual:
        from .layers import init_mlp

        p["dense"] = init_mlp(ks[4], d, cfg.dense_d_ff or cfg.d_ff, cfg)
    return p


def _expert_ffn(pe, cfg, buf):
    """buf [E, C, d] -> [E, C, d], batched over experts."""
    if cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, pe["gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", buf, pe["up"])
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", buf, pe["up"])))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, pe["up"]))
    return jnp.einsum("ecf,efd->ecd", h, pe["down"])


def apply_moe(p, cfg, x, capacity_factor: float = 1.25, n_groups: int | None = None):
    """x [B,S,d] -> ([B,S,d], aux_loss).

    Group-local dispatch: tokens are partitioned into G groups aligned with
    the data shards; all position bookkeeping (cumsum over the one-hot
    assignment) happens *within* a group, so it is shard-local under
    GSPMD — no cross-device dependency exists before the single
    buffers-to-experts all_to_all (the unavoidable EP redistribution,
    exactly the paper's vertical->horizontal layout switch). The earlier
    global-cumsum formulation serialized a [T*k, E] prefix sum across the
    whole mesh and dominated the collective roofline term (§Perf log).
    """
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    G = n_groups or min(B, 32)  # groups align with batch/data shards
    Tg = T // G
    xt = x.reshape(G, Tg, d)
    logits = (xt @ p["router"]).astype(jnp.float32)  # [G, Tg, E]
    gates, idx = lax.top_k(logits, k)  # [G, Tg, k]
    gates = jax.nn.softmax(gates, axis=-1)

    # load-balancing auxiliary loss (Switch-style)
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=2), axis=(0, 1)
    )
    frac_probs = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)

    Cg = max(int(Tg * k / E * capacity_factor), 1)
    flat_e = idx.reshape(G, Tg * k)  # expert of each (token, slot) per group
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # [G, Tg*k, E]
    pos_all = jnp.cumsum(oh, axis=1) - oh  # group-local prefix sums
    pos = jnp.take_along_axis(pos_all, flat_e[..., None], axis=2)[..., 0]
    keep = pos < Cg
    pos_c = jnp.where(keep, pos, Cg)  # dropped tokens land in slot Cg

    src = jnp.repeat(xt, k, axis=1)  # [G, Tg*k, d]
    buf = jnp.zeros((G, E, Cg + 1, d), x.dtype)
    gidx = jnp.arange(G)[:, None] * jnp.ones_like(flat_e)
    buf = buf.at[gidx, flat_e, pos_c].add(src, mode="drop")
    out_buf = _expert_ffn_grouped(p["experts"], cfg, buf[:, :, :Cg])
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((G, E, 1, d), out_buf.dtype)], axis=2)
    gathered = out_buf[gidx, flat_e, pos_c]  # [G, Tg*k, d]
    w = (gates.reshape(G, Tg * k) * keep).astype(x.dtype)
    y = (gathered * w[..., None]).reshape(G, Tg, k, d).sum(axis=2)
    y = y.reshape(B, S, d)
    if "dense" in p:
        from .layers import apply_mlp

        y = y + apply_mlp(p["dense"], x, cfg.activation)
    return y, aux * cfg.router_aux_coef


def _expert_ffn_grouped(pe, cfg, buf):
    """buf [G, E, Cg, d] -> same; the g axis rides along the expert batch
    (the [G->E] resharding here is the one EP all_to_all)."""
    # NOTE (§Perf iteration log): forcing the ZeRO-stored weights to be
    # re-gathered here (with_sharding_constraint to replicated) removed
    # 14.6 s of collective time but re-ran the full expert compute on every
    # model shard (26x flops) — net regression, reverted.
    if cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, pe["gate"]))
        h = h * jnp.einsum("gecd,edf->gecf", buf, pe["up"])
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(jnp.einsum("gecd,edf->gecf", buf, pe["up"])))
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", buf, pe["up"]))
    return jnp.einsum("gecf,efd->gecd", h, pe["down"])


def apply_moe_decode(p, cfg, x):
    """Single-token MoE (decode): dense top-k gather, no capacity buffers.

    x [B,1,d]; with B small, computing the k selected experts per token via
    gathered weight slices is cheaper than buffer dispatch.
    """
    B, _, d = x.shape
    xt = x.reshape(B, d)
    logits = (xt @ p["router"]).astype(jnp.float32)
    gates, idx = lax.top_k(logits, cfg.top_k)  # [B, k]
    gates = jax.nn.softmax(gates, axis=-1)
    pe = p["experts"]

    def one_expert(e_idx, xi):
        if cfg.activation == "swiglu":
            h = jax.nn.silu(xi @ pe["gate"][e_idx]) * (xi @ pe["up"][e_idx])
        elif cfg.activation == "squared_relu":
            h = jnp.square(jax.nn.relu(xi @ pe["up"][e_idx]))
        else:
            h = jax.nn.gelu(xi @ pe["up"][e_idx])
        return h @ pe["down"][e_idx]

    # [B, k, d] via vmap over batch and slots
    y = jax.vmap(lambda ei, xi: jax.vmap(lambda e: one_expert(e, xi))(ei))(idx, xt)
    y = (y * gates[..., None].astype(y.dtype)).sum(axis=1).reshape(B, 1, d)
    if "dense" in p:
        from .layers import apply_mlp

        y = y + apply_mlp(p["dense"], x, cfg.activation)
    return y

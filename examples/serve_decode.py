"""Batched serving demo: prefill a batch of prompts, decode with ring
caches (sliding-window + global layers on the hymba hybrid).

    PYTHONPATH=src python examples/serve_decode.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import (init_train_state, make_decode_step,
                          make_prefill_step)
from repro.optim import AdamWConfig


def main():
    cfg = get_smoke_config("hymba-1.5b")
    print(f"serving {cfg.name}: window={cfg.sliding_window}, "
          f"global layers={cfg.global_attn_layers}, ssm_state={cfg.ssm_state}")
    params, _ = init_train_state(cfg, AdamWConfig(), jax.random.PRNGKey(0))
    B, prompt_len, gen_len, max_len = 4, 24, 24, 64
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, prompt_len)), jnp.int32)

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    logits, state = prefill(params, {"tokens": prompts})
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [np.asarray(tok)]
    import time

    t0 = time.perf_counter()
    for pos in range(prompt_len, prompt_len + gen_len):
        logits, state = decode(params, state, tok, jnp.asarray(pos, jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    gen = np.stack(out, axis=1)
    print(f"generated {gen_len} tokens x {B} sequences in {dt:.2f}s "
          f"({B*gen_len/dt:.0f} tok/s, ring caches crossed the "
          f"{cfg.sliding_window}-token window {'' if prompt_len+gen_len > cfg.sliding_window else 'not '}boundary)")
    for b in range(2):
        print(f"  seq{b}: {gen[b][:12].tolist()} ...")
    assert np.isfinite(np.asarray(logits)).all()
    print("OK")


if __name__ == "__main__":
    main()

"""Two orthogonal layers of parallelism, end to end.

    PYTHONPATH=src python examples/eigensolve_panel.py

Runs the SAME eigenproblem three ways on an 8-device mesh —
stack (8x1), panel (4x2), pillar (1x8) — and reports, per layout:
iterations, SpMVs, redistribution count/time, and the per-SpMV collective
bytes measured from the compiled HLO (which follow the χ metric exactly).
The eigenvalues agree across layouts and with dense eigh.

This script re-executes itself with 8 fake XLA devices.
"""
import os
import subprocess
import sys

if "XLA_FLAGS" not in os.environ:
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8")
    sys.exit(subprocess.run([sys.executable] + sys.argv, env=env).returncode)

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import FDConfig, FilterDiag, make_solver_mesh, panel, pillar, stack
from repro.core.layouts import Layout
from repro.core.metrics import chi_metrics
from repro.matrices import Hubbard


def main():
    mat = Hubbard(n_sites=6, n_fermions=3, U=4.0, ranpot=1.0)
    csr = mat.build_csr()
    w = np.linalg.eigvalsh(csr.to_dense())
    tau = float(w[len(w) // 3])
    print(f"matrix: {mat.describe()}, target tau={tau:+.4f}")
    for Np in (2, 4, 8):
        m = chi_metrics(mat, Np)
        print(f"  chi[{Np}] = {m.chi1:.2f}  (comm-bound for chi >> b_c/b_m)")

    results = {}
    for n_row, n_col, name in ((8, 1, "stack"), (4, 2, "panel 4x2"),
                               (1, 8, "pillar")):
        mesh = make_solver_mesh(n_row, n_col)
        cfg = FDConfig(n_target=3, n_search=16, target=tau, tol=1e-8,
                       max_iters=18)
        with mesh:
            fd = FilterDiag(csr, mesh, cfg)
            res = fd.solve()
        results[name] = res
        pct = 100 * res.redist_time / max(res.wall_time, 1e-9)
        comm = fd.ell_panel.comm_bytes_per_spmv
        print(f"[{name:9s}] conv={res.n_converged} iters={res.iterations} "
              f"spmvs={res.total_spmvs} redists={res.redistributions} "
              f"(redist {pct:.1f}% of wall) "
              f"filter-SpMV comm plan: {comm/1024:.0f} KiB/column-group")

    evs = [np.sort(r.eigenvalues[:3]) for r in results.values()]
    for e in evs[1:]:
        np.testing.assert_allclose(e[:3], evs[0][:3], atol=1e-7)
    for ev in evs[0]:
        assert np.abs(w - ev).min() < 1e-7
    print("OK — all layouts agree with each other and with dense eigh")


if __name__ == "__main__":
    main()

"""Eigensolve-as-a-service demo: batched multi-tenant filter diagonalization.

Three tenants request eigenpairs of the same spin chain at different
spectral targets. The service plans the operator once (persisting the
plan to a JSON cache — rerun this script and watch the planner be
skipped), batches the three requests into ONE SpMV panel (the paper's
vertical layer as a request-batching dimension: extra vector columns,
zero extra halo exchanges), checkpoints every iteration, and demuxes
per-request results bit-identically to solo solves.

    PYTHONPATH=src python examples/serve_eigensolve.py
"""
import os
import tempfile

import numpy as np
import jax

jax.config.update("jax_enable_x64", True)

from repro.service import EigenService, PlanCache, SolveRequest  # noqa: E402


def main():
    work = tempfile.mkdtemp(prefix="eigenservice_")
    cache = PlanCache(os.path.join(work, "plans.json"))
    svc = EigenService(plan_cache=cache,
                       ckpt_root=os.path.join(work, "ckpt"))

    spin = dict(family="SpinChainXXZ", params=dict(n_sites=10, n_up=5))
    svc.submit(SolveRequest("tenant-a", **spin, n_target=4, n_search=16,
                            target=-3.0, tol=1e-9, seed=11))
    svc.submit(SolveRequest("tenant-b", **spin, n_target=4, n_search=16,
                            target=0.0, tol=1e-9, seed=22))
    svc.submit(SolveRequest("tenant-c", **spin, n_target=4, n_search=16,
                            target=1.5, tol=1e-9, seed=33))

    results = svc.drain()
    print(f"plan cache: hits={cache.hits} misses={cache.misses} "
          f"planner calls={cache.plan_calls}  ({cache.path})")
    for rid in sorted(results):
        r = results[rid]
        print(f"[{rid}] {r.n_converged} converged in {r.iterations} "
              f"iterations / {r.total_spmvs} SpMVs: "
              f"{np.array2string(np.sort(r.eigenvalues), precision=8)}")

    # solo re-solve of tenant-a demuxes to the exact batched values
    solo = EigenService(plan_cache=cache)
    solo.submit(SolveRequest("tenant-a", **spin, n_target=4, n_search=16,
                             target=-3.0, tol=1e-9, seed=11))
    r_solo = solo.drain()["tenant-a"]
    same = np.array_equal(r_solo.eigenvalues, results["tenant-a"].eigenvalues)
    print(f"solo == batched (bit-identical demux): {same}; "
          f"cache hits now {cache.hits} (planner never re-ran)")


if __name__ == "__main__":
    main()

"""KPM density of states (paper Figs. 7/8, reduced scale).

    PYTHONPATH=src python examples/dos_kpm.py

Computes the kernel-polynomial-method DOS of a Hubbard matrix with the
same distributed Chebyshev machinery as the FD filter (stochastic trace
over random vectors), and validates the histogram against dense eigh.
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import jax.numpy as jnp

from repro.core import build_dist_ell, make_solver_mesh, make_spmv, stack
from repro.core.chebyshev import kpm_dos, kpm_moments, scale_params
from repro.core.lanczos import lanczos_interval
from repro.matrices import Hubbard


def main():
    mat = Hubbard(8, 4, U=6.0, ranpot=1.0)
    csr = mat.build_csr()
    D = csr.shape[0]
    print(f"matrix: {mat.describe()}")
    mesh = make_solver_mesh(1, 1)
    with mesh:
        lay = stack(mesh)
        ell = build_dist_ell(csr, 1)
        spmv = make_spmv(mesh, lay, ell)
        lam = lanczos_interval(spmv, D, ell.R * ell.P, jnp.float64,
                               jax.random.PRNGKey(0))
        alpha, beta = scale_params(*lam)
        key = jax.random.PRNGKey(1)
        R = jax.random.rademacher(key, (ell.R * ell.P, 16), jnp.float64)
        R = R * (jnp.arange(ell.R * ell.P)[:, None] < D)
        mu = np.asarray(kpm_moments(spmv, alpha, beta, R, n_moments=256)) / 16
    x, rho = kpm_dos(mu, n_bins=256)
    lam_axis = (x - beta) / alpha

    # validate against the exact spectrum histogram
    w = np.linalg.eigvalsh(csr.to_dense())
    # fraction of eigenvalues below the U-gap, KPM vs exact
    split = float(np.median(w))
    kpm_frac = float(np.trapezoid(rho * (lam_axis < split), lam_axis)
                     / np.trapezoid(rho, lam_axis))
    true_frac = float((w < split).mean())
    print(f"spectral weight below lambda={split:.2f}: KPM {kpm_frac:.3f} "
          f"vs exact {true_frac:.3f}")
    assert abs(kpm_frac - true_frac) < 0.05
    # coarse DOS shape: correlation between KPM and exact histograms
    hist, edges = np.histogram(w, bins=48, range=(lam_axis[0], lam_axis[-1]),
                               density=True)
    centers = 0.5 * (edges[1:] + edges[:-1])
    kpm_on_centers = np.interp(centers, lam_axis, rho * alpha)
    corr = np.corrcoef(hist, kpm_on_centers)[0, 1]
    print(f"DOS shape correlation (48 bins): {corr:.3f}")
    assert corr > 0.9
    print("OK — KPM DOS matches the exact spectrum (Figs. 7/8 machinery)")


if __name__ == "__main__":
    main()

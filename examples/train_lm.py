"""End-to-end LM training driver (~115M-parameter config, CPU-feasible).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--small]

Trains a reduced qwen3-family model on the structured synthetic corpus
with the full production substrate: deterministic restartable pipeline,
AdamW (+cosine schedule, grad clip), checkpointing, health tracking.
``--small`` uses the smoke config for a fast demonstration run.
"""
import argparse

import numpy as np
import jax

from repro.models.config import ModelConfig
from repro.models import init_train_state, make_train_step
from repro.data import TokenPipeline
from repro.optim import AdamWConfig
from repro.checkpoint import CheckpointManager
from repro.runtime import StepTimer


def lm_100m() -> ModelConfig:
    # ~115M params: qwen3-family block (qk_norm, GQA, swiglu, tied embed)
    return ModelConfig(
        name="repro-115m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32768, qk_norm=True,
        tie_embeddings=True, dtype="float32", loss_chunk=128,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.small:
        from repro.configs import get_smoke_config

        cfg = get_smoke_config("qwen3-0.6b")
        args.steps = min(args.steps, 60)
    else:
        cfg = lm_100m()
    print(f"config {cfg.name}: {cfg.n_params()/1e6:.1f}M params")
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                       moment_dtype="float32")
    params, opt_state = init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(cfg)
    step = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))
    manager = CheckpointManager(args.ckpt_dir, interval=max(args.steps // 3, 1))
    timer = StepTimer()
    first = None
    for i in range(args.steps):
        batch = pipe.batch(i, args.batch, args.seq)
        timer.start()
        params, opt_state, m = step(params, opt_state, batch)
        loss = float(m["loss"])
        timer.stop()
        first = first if first is not None else loss
        manager.maybe_save(i, (params, opt_state), extra={"pipeline_index": i})
        if i % 20 == 0 or i == args.steps - 1:
            tok_s = args.batch * args.seq / max(timer.ewma, 1e-9)
            print(f"step {i:5d}  loss {loss:.4f}  grad_norm "
                  f"{float(m['grad_norm']):.2f}  {tok_s:,.0f} tok/s")
    print(f"\nloss {first:.3f} -> {loss:.3f} over {args.steps} steps")
    assert loss < first - 0.3, "loss did not decrease"
    print("OK")


if __name__ == "__main__":
    main()

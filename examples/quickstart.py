"""Quickstart: filter diagonalization of a spin chain, validated vs eigh.

    PYTHONPATH=src python examples/quickstart.py

Computes 4 interior eigenpairs of the XXZ chain (D = 3432) with the plain
stack layout (single device) and checks them against dense eigh.
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import FDConfig, FilterDiag, make_solver_mesh
from repro.matrices import SpinChainXXZ


def main():
    mat = SpinChainXXZ(n_sites=14, n_up=7)
    csr = mat.build_csr()
    print(f"matrix: {mat.describe()}  nnz/row={csr.n_nzr:.1f}")

    w = np.linalg.eigvalsh(csr.to_dense())
    tau = float(w[len(w) // 2])  # an *interior* target — the hard case
    print(f"target tau = {tau:+.6f} (median of {len(w)} eigenvalues)")

    mesh = make_solver_mesh(1, 1)
    cfg = FDConfig(n_target=4, n_search=16, target=tau, tol=1e-9, max_iters=30)
    with mesh:
        res = FilterDiag(csr, mesh, cfg).solve(verbose=True)

    print(f"\nconverged {res.n_converged} eigenpairs in {res.iterations} "
          f"iterations ({res.total_spmvs} SpMVs)")
    for ev, r in zip(res.eigenvalues[:4], res.residuals[:4]):
        true = w[np.argmin(np.abs(w - ev))]
        print(f"  lambda = {ev:+.12f}  (eigh {true:+.12f}, "
              f"delta {abs(ev-true):.2e}, residual {r:.2e})")
    assert all(np.abs(w - ev).min() < 1e-8 for ev in res.eigenvalues[:4])
    print("OK — matches dense eigh")


if __name__ == "__main__":
    main()
